//! CLI integration: drive the `snipsnap` binary end to end.

use std::process::Command;

fn snipsnap() -> Command {
    Command::new(env!("CARGO_BIN_EXE_snipsnap"))
}

/// Smoke test keeping the binary target wired into `cargo test`: `snipsnap
/// list` must exit 0 and name at least one arch preset, one workload
/// preset and the metric list.
#[test]
fn smoke_list_exits_zero_and_names_presets() {
    let out = snipsnap().arg("list").output().expect("run");
    assert_eq!(out.status.code(), Some(0), "non-zero exit: {:?}", out.status);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("arch1"), "no arch preset named:\n{stdout}");
    assert!(stdout.contains("arch3"), "no arch preset named:\n{stdout}");
    assert!(stdout.contains("llama2-7b"), "no workload preset named:\n{stdout}");
    assert!(stdout.contains("opt-125m"), "no workload preset named:\n{stdout}");
    assert!(stdout.contains("metrics:"), "no metric list:\n{stdout}");
    // The scenario zoo families must all be advertised.
    assert!(stdout.contains("llama3-8b"), "no GQA preset:\n{stdout}");
    assert!(stdout.contains("mixtral-8x7b"), "no MoE preset:\n{stdout}");
    assert!(stdout.contains("batched decode"), "no batched-decode family:\n{stdout}");
    assert!(stdout.contains("decode-tiny"), "no batched-decode preset:\n{stdout}");
    assert!(stdout.contains("--nm N:M"), "no N:M modifier:\n{stdout}");
    assert!(stdout.contains("llama2-7b-nm24"), "no N:M preset:\n{stdout}");
    // The quantized presets and the quant-axis flags must be advertised.
    assert!(stdout.contains("llama2-7b-w4a8"), "no fixed-width quant preset:\n{stdout}");
    assert!(stdout.contains("llama2-7b-qsearch"), "no quant-search preset:\n{stdout}");
    assert!(stdout.contains("--w-bits"), "no quant flags mentioned:\n{stdout}");
}

/// Scenario presets drive the whole pipeline from the CLI, including
/// the workload modifier flags.
#[test]
fn search_scenario_preset_with_modifiers() {
    let out = snipsnap()
        .args([
            "search", "--arch", "arch3", "--workload", "moe-tiny", "--mode", "fixed",
            "--max-mappings", "200", "--prefill", "32", "--decode", "4", "--batch", "2",
            "--kv-density", "0.5", "--nm", "2:4",
        ])
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("expert_fc1"), "no MoE expert ops:\n{stdout}");
    assert!(stdout.contains("totals:"), "{stdout}");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("W2:4"), "N:M variant not applied:\n{stderr}");
}

/// Out-of-range scenario knobs must fail with a clear message, not
/// silently produce nonsense costs.
#[test]
fn bad_scenario_modifiers_fail_cleanly() {
    let run = |args: &[&str]| {
        let out = snipsnap().args(args).output().expect("run");
        assert!(!out.status.success(), "{args:?} unexpectedly succeeded");
        String::from_utf8_lossy(&out.stderr).to_string()
    };
    let e = run(&["search", "--workload", "gqa-tiny", "--kv-density", "1.5"]);
    assert!(e.contains("kv_density"), "{e}");
    let e = run(&["search", "--workload", "gqa-tiny", "--nm", "junk"]);
    assert!(e.contains("N:M"), "{e}");
    let e = run(&["search", "--workload", "alexnet", "--batch", "2"]);
    assert!(e.contains("transformer"), "{e}");
    // Modifier flags cannot silently lose against a --config file.
    let e = run(&["search", "--config", "nonexistent.toml", "--nm", "2:4"]);
    assert!(e.contains("cannot be combined"), "{e}");
}

#[test]
fn no_args_prints_usage() {
    let out = snipsnap().output().expect("run");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("USAGE"));
}

/// Regression: an unknown subcommand must print usage to stderr and exit
/// non-zero (never 0 — scripts rely on the exit code).
#[test]
fn unknown_subcommand_prints_usage_and_exits_nonzero() {
    let out = snipsnap().arg("frobnicate").output().expect("run");
    assert!(
        !out.status.success(),
        "unknown subcommand exited with success: {:?}",
        out.status
    );
    assert_eq!(out.status.code(), Some(2), "usage errors exit 2");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown subcommand 'frobnicate'"), "{stderr}");
    assert!(stderr.contains("USAGE"), "usage must go to stderr:\n{stderr}");
    assert!(out.stdout.is_empty(), "nothing belongs on stdout here");
}

#[test]
fn formats_subcommand_reports_top_formats() {
    let out = snipsnap()
        .args(["formats", "--rows", "256", "--cols", "256", "--density", "0.1"])
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("Top formats"), "{stdout}");
    assert!(stdout.contains("ratio"));
}

#[test]
fn search_with_inline_config() {
    let dir = std::env::temp_dir().join("snipsnap_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("run.toml");
    std::fs::write(
        &cfg,
        r#"
[run]
arch = "arch3"
metric = "energy"
mode = "fixed"
[search]
max_mappings = 300
[op.g]
m = 64
n = 64
k = 64
act_density = 0.5
wgt_density = 0.5
"#,
    )
    .unwrap();
    let out = snipsnap()
        .args(["search", "--config", cfg.to_str().unwrap(), "--threads", "2"])
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("totals:"), "{stdout}");
    assert!(stdout.contains("evaluations"));
    assert!(stdout.contains("(2 threads)"), "{stdout}");
    assert!(stdout.contains("cache: access-counts"), "{stdout}");
    assert!(stdout.contains("enumeration:"), "{stdout}");
    assert!(stdout.contains("pruned by lower bound"), "{stdout}");
}

/// The replayable-artifact contract at the CLI surface: a search run
/// emits a JSON run-config snapshot which, fed back via --config,
/// reproduces the design table and totals byte for byte.
#[test]
fn snapshot_replays_identically_through_config() {
    let dir = std::env::temp_dir().join("snipsnap_cli_snapshot");
    std::fs::create_dir_all(&dir).unwrap();
    let snap = dir.join("run.config.json");
    let _ = std::fs::remove_file(&snap);
    let out1 = snipsnap()
        .args([
            "search", "--arch", "arch3", "--workload", "gqa-tiny", "--mode", "fixed",
            "--max-mappings", "200", "--prefill", "32", "--decode", "4",
            "--snapshot", snap.to_str().unwrap(),
        ])
        .output()
        .expect("run");
    assert!(out1.status.success(), "{}", String::from_utf8_lossy(&out1.stderr));
    let stderr1 = String::from_utf8_lossy(&out1.stderr);
    assert!(stderr1.contains("run-config snapshot:"), "{stderr1}");
    let text = std::fs::read_to_string(&snap).expect("snapshot written");
    assert!(text.trim_start().starts_with('{'), "snapshot must be JSON:\n{text}");
    assert!(text.contains("snipsnap_run_config"), "{text}");

    let out2 = snipsnap()
        .args(["search", "--config", snap.to_str().unwrap(), "--snapshot", "off"])
        .output()
        .expect("replay");
    assert!(out2.status.success(), "{}", String::from_utf8_lossy(&out2.stderr));
    // Timing/counter lines vary run to run; the design table and totals
    // (every format pick, energy and cycle figure) must not.
    let stable = |s: &str| -> String {
        s.lines()
            .filter(|l| {
                !l.starts_with("search:") && !l.starts_with("cache:")
                    && !l.starts_with("enumeration:")
            })
            .collect::<Vec<_>>()
            .join("\n")
    };
    assert_eq!(
        stable(&String::from_utf8_lossy(&out1.stdout)),
        stable(&String::from_utf8_lossy(&out2.stdout)),
        "replayed run diverged from the original"
    );
}

/// `--cost-backend contention` selects the backend end to end: it is
/// announced on stderr, captured in the snapshot, and the snapshot
/// replays the identical run (docs/COST.md).
#[test]
fn cost_backend_flag_selects_and_snapshots_contention() {
    let dir = std::env::temp_dir().join("snipsnap_cli_cost_backend");
    std::fs::create_dir_all(&dir).unwrap();
    let snap = dir.join("run.config.json");
    let _ = std::fs::remove_file(&snap);
    let out1 = snipsnap()
        .args([
            "search", "--arch", "arch3", "--workload", "gqa-tiny", "--mode", "fixed",
            "--metric", "latency", "--max-mappings", "200", "--prefill", "32", "--decode", "4",
            "--cost-backend", "contention", "--snapshot", snap.to_str().unwrap(),
        ])
        .output()
        .expect("run");
    assert!(out1.status.success(), "{}", String::from_utf8_lossy(&out1.stderr));
    let stderr1 = String::from_utf8_lossy(&out1.stderr);
    assert!(stderr1.contains("cost backend: contention"), "{stderr1}");
    let text = std::fs::read_to_string(&snap).expect("snapshot written");
    assert!(text.contains(r#""backend":"contention""#), "{text}");
    assert!(text.contains("bandwidth_derate"), "{text}");

    // The snapshot replays the contention run byte for byte (stable
    // lines only, as in snapshot_replays_identically_through_config).
    let out2 = snipsnap()
        .args(["search", "--config", snap.to_str().unwrap(), "--snapshot", "off"])
        .output()
        .expect("replay");
    assert!(out2.status.success(), "{}", String::from_utf8_lossy(&out2.stderr));
    assert!(
        String::from_utf8_lossy(&out2.stderr).contains("cost backend: contention"),
        "replay lost the backend"
    );
    let stable = |s: &str| -> String {
        s.lines()
            .filter(|l| {
                !l.starts_with("search:") && !l.starts_with("cache:")
                    && !l.starts_with("enumeration:")
            })
            .collect::<Vec<_>>()
            .join("\n")
    };
    assert_eq!(
        stable(&String::from_utf8_lossy(&out1.stdout)),
        stable(&String::from_utf8_lossy(&out2.stdout)),
        "replayed contention run diverged from the original"
    );
}

/// A bogus backend name is a usage error: exit 2, usage on stderr.
#[test]
fn bad_cost_backend_exits_2_with_usage() {
    let out = snipsnap()
        .args(["search", "--workload", "gqa-tiny", "--cost-backend", "bogus"])
        .output()
        .expect("run");
    assert_eq!(out.status.code(), Some(2), "usage errors exit 2: {:?}", out.status);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown cost backend 'bogus'"), "{stderr}");
    assert!(stderr.contains("USAGE"), "usage must go to stderr:\n{stderr}");
}

/// The quant-axis flags drive the search end to end: a fixed width and a
/// comma-separated search set are both accepted, the axis is announced
/// on stderr, and the chosen widths land in the design table's
/// `bits (A/W)` column (docs/SEARCH.md).
#[test]
fn quant_flags_accept_fixed_and_set_widths() {
    let out = snipsnap()
        .args([
            "search", "--arch", "arch3", "--workload", "gqa-tiny", "--mode", "fixed",
            "--max-mappings", "200", "--w-bits", "4", "--a-bits", "8",
        ])
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("quant axis: W{4} A{8}"), "axis not announced:\n{stderr}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("bits (A/W)"), "no widths column:\n{stdout}");
    assert!(stdout.contains("8/4"), "fixed widths not in the table:\n{stdout}");

    let out = snipsnap()
        .args([
            "search", "--arch", "arch3", "--workload", "gqa-tiny", "--mode", "fixed",
            "--max-mappings", "200", "--w-bits", "4,8,16", "--a-bits", "8",
        ])
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("quant axis: W{4,8,16} A{8}"), "set not announced:\n{stderr}");
}

/// Bogus quant widths are usage errors: exit 2, usage on stderr — zero,
/// trailing commas, non-numbers and widths above the accelerator's
/// `data_bits` all fail before any search runs.
#[test]
fn bad_quant_flags_exit_2_with_usage() {
    let run = |val: &str| {
        let out = snipsnap()
            .args(["search", "--workload", "gqa-tiny", "--w-bits", val])
            .output()
            .expect("run");
        assert_eq!(
            out.status.code(),
            Some(2),
            "--w-bits {val}: usage errors exit 2: {:?}",
            out.status
        );
        let stderr = String::from_utf8_lossy(&out.stderr).to_string();
        assert!(stderr.contains("USAGE"), "--w-bits {val}: usage must go to stderr:\n{stderr}");
        stderr
    };
    let e = run("0");
    assert!(e.contains("out of range"), "{e}");
    let e = run("3,");
    assert!(e.contains("cannot parse"), "{e}");
    let e = run("foo");
    assert!(e.contains("cannot parse"), "{e}");
    let e = run("32");
    assert!(e.contains("data_bits"), "widths above the word width must fail:\n{e}");
}

/// The replayable-artifact contract extends to the quant axis: a search
/// with quant flags snapshots the `[quant]` spaces and the snapshot
/// replays the identical run through --config.
#[test]
fn quant_snapshot_replays_identically_through_config() {
    let dir = std::env::temp_dir().join("snipsnap_cli_quant_snapshot");
    std::fs::create_dir_all(&dir).unwrap();
    let snap = dir.join("run.config.json");
    let _ = std::fs::remove_file(&snap);
    let out1 = snipsnap()
        .args([
            "search", "--arch", "arch3", "--workload", "gqa-tiny", "--mode", "fixed",
            "--max-mappings", "200", "--prefill", "32", "--decode", "4",
            "--w-bits", "4,8,16", "--a-bits", "8", "--kv-bits", "8",
            "--snapshot", snap.to_str().unwrap(),
        ])
        .output()
        .expect("run");
    assert!(out1.status.success(), "{}", String::from_utf8_lossy(&out1.stderr));
    assert!(
        String::from_utf8_lossy(&out1.stderr).contains("quant axis:"),
        "axis not announced"
    );
    let text = std::fs::read_to_string(&snap).expect("snapshot written");
    assert!(text.contains(r#""w_bits":[4,8,16]"#), "quant not captured:\n{text}");

    let out2 = snipsnap()
        .args(["search", "--config", snap.to_str().unwrap(), "--snapshot", "off"])
        .output()
        .expect("replay");
    assert!(out2.status.success(), "{}", String::from_utf8_lossy(&out2.stderr));
    assert!(
        String::from_utf8_lossy(&out2.stderr).contains("quant axis:"),
        "replay lost the quant axis"
    );
    let stable = |s: &str| -> String {
        s.lines()
            .filter(|l| {
                !l.starts_with("search:") && !l.starts_with("cache:")
                    && !l.starts_with("enumeration:")
            })
            .collect::<Vec<_>>()
            .join("\n")
    };
    assert_eq!(
        stable(&String::from_utf8_lossy(&out1.stdout)),
        stable(&String::from_utf8_lossy(&out2.stdout)),
        "replayed quant run diverged from the original"
    );
}

/// `snipsnap report` renders a summary from accumulated records and
/// fails (non-zero) on unparseable artifacts.
#[test]
fn report_rolls_up_results_and_rejects_rot() {
    let dir = std::env::temp_dir().join("snipsnap_cli_report/results");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("demo.jsonl"),
        "{\"bench\":\"demo\",\"git_rev\":\"aaa\",\"ts_unix\":1,\"wall_time_s\":1.0,\
         \"rows\":{\"metric\":2.0}}\n\
         {\"bench\":\"demo\",\"git_rev\":\"bbb\",\"ts_unix\":2,\"wall_time_s\":1.5,\
         \"rows\":{\"metric\":3.0}}\n",
    )
    .unwrap();
    let out = snipsnap()
        .args(["report", "--dir", dir.to_str().unwrap()])
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("demo"), "{stdout}");
    assert!(stdout.contains("bbb"), "latest rev must render:\n{stdout}");
    assert!(stdout.contains("metric: 2 -> 3"), "trajectory diff missing:\n{stdout}");
    assert!(stdout.contains("WALL-REGRESSION"), "{stdout}");

    std::fs::write(dir.join("rotten.jsonl"), "{not json\n").unwrap();
    let out = snipsnap()
        .args(["report", "--dir", dir.to_str().unwrap()])
        .output()
        .expect("run");
    assert!(!out.status.success(), "parse errors must fail the report");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("rotten.jsonl"), "{stderr}");
}

/// Regression: flags outside the subcommand's allowlist are usage
/// errors — exit 2, a message naming the flag and the subcommand, usage
/// on stderr, nothing on stdout.  A typo like `--thread 4` must never
/// silently run single-threaded.
#[test]
fn unknown_flags_exit_2_with_usage() {
    let run = |args: &[&str]| {
        let out = snipsnap().args(args).output().expect("run");
        assert_eq!(out.status.code(), Some(2), "{args:?}: usage errors exit 2: {:?}", out.status);
        assert!(out.stdout.is_empty(), "{args:?}: nothing belongs on stdout");
        let stderr = String::from_utf8_lossy(&out.stderr).to_string();
        assert!(stderr.contains("USAGE"), "{args:?}: usage must go to stderr:\n{stderr}");
        stderr
    };
    let e = run(&["search", "--thread", "4"]);
    assert!(e.contains("unknown flag '--thread' for 'snipsnap search'"), "{e}");
    // Flags valid for one subcommand are still rejected on another.
    let e = run(&["search", "--jobs", "2"]);
    assert!(e.contains("unknown flag '--jobs' for 'snipsnap search'"), "{e}");
    let e = run(&["report", "--once"]);
    assert!(e.contains("unknown flag '--once' for 'snipsnap report'"), "{e}");
    let e = run(&["serve", "--plan", "x.toml"]);
    assert!(e.contains("unknown flag '--plan' for 'snipsnap serve'"), "{e}");
    let e = run(&["sweep", "--snapshot", "off"]);
    assert!(e.contains("unknown flag '--snapshot' for 'snipsnap sweep'"), "{e}");
}

/// `--memo-max-entries` needs a store to cap: combining it with
/// `--memo off` is an error, and a zero cap is rejected.
#[test]
fn serve_memo_cap_requires_a_store() {
    let out = run_with_stdin(
        &["serve", "--once", "--memo", "off", "--memo-max-entries", "5", "--results", "off"],
        "",
    );
    assert!(!out.status.success(), "--memo off + a cap must fail");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--memo-max-entries requires a memo store"), "{stderr}");

    let out = run_with_stdin(
        &["serve", "--once", "--memo", "off", "--memo-max-entries", "0", "--results", "off"],
        "",
    );
    assert!(!out.status.success(), "a zero cap must fail");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--memo-max-entries must be >= 1"), "{stderr}");
}

#[test]
fn bad_flags_fail_cleanly() {
    let out = snipsnap()
        .args(["search", "--arch", "not-an-arch"])
        .output()
        .expect("run");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown arch"), "{stderr}");

    let out = snipsnap().args(["formats", "--rows", "64"]).output().expect("run");
    assert!(!out.status.success());
}

/// Spawn the binary with `input` piped to stdin and collect the output.
fn run_with_stdin(args: &[&str], input: &str) -> std::process::Output {
    use std::io::Write;
    use std::process::Stdio;
    let mut child = snipsnap()
        .args(args)
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn");
    // Take (and drop) the handle so the child sees EOF after the write.
    child.stdin.take().unwrap().write_all(input.as_bytes()).expect("write stdin");
    child.wait_with_output().expect("wait")
}

/// `snipsnap serve --once` end to end: a snapshot emitted by `snipsnap
/// search` is a valid request body verbatim, two identical requests
/// yield byte-identical stdout, the second run's stderr reports a
/// nonzero cross-run memo hit count (the store persisted), and the
/// per-request records roll up under `snipsnap report`.
#[test]
fn serve_once_round_trips_and_warms_the_memo() {
    let dir = std::env::temp_dir().join("snipsnap_cli_serve");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let snap = dir.join("req.config.json");
    let out = snipsnap()
        .args([
            "search", "--arch", "arch3", "--workload", "gqa-tiny", "--mode", "fixed",
            "--max-mappings", "200", "--prefill", "32", "--decode", "4",
            "--snapshot", snap.to_str().unwrap(),
        ])
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let request = std::fs::read_to_string(&snap).expect("snapshot written");

    let memo = dir.join("memo.jsonl");
    let results = dir.join("results");
    let args = [
        "serve", "--once",
        "--memo", memo.to_str().unwrap(),
        "--results", results.to_str().unwrap(),
    ];
    let out1 = run_with_stdin(&args, &request);
    assert!(out1.status.success(), "{}", String::from_utf8_lossy(&out1.stderr));
    let stdout1 = String::from_utf8_lossy(&out1.stdout);
    assert!(stdout1.contains("\"ok\":true"), "{stdout1}");
    assert!(stdout1.contains("\"designs\":"), "{stdout1}");
    let stderr1 = String::from_utf8_lossy(&out1.stderr);
    assert!(stderr1.contains("memo_hits="), "no stats line:\n{stderr1}");
    assert!(stderr1.contains("1 requests served, 0 failed"), "{stderr1}");
    assert!(memo.exists(), "the memo store must persist to disk");

    // Replay: a fresh process, same request, warmed store.
    let out2 = run_with_stdin(&args, &request);
    assert!(out2.status.success(), "{}", String::from_utf8_lossy(&out2.stderr));
    assert_eq!(
        out1.stdout, out2.stdout,
        "identical requests must produce byte-identical responses"
    );
    let stderr2 = String::from_utf8_lossy(&out2.stderr);
    let hits: u64 = stderr2
        .lines()
        .find_map(|l| l.split("memo_hits=").nth(1))
        .and_then(|s| s.split_whitespace().next())
        .expect("memo_hits= in stats")
        .parse()
        .expect("memo_hits is a number");
    assert!(hits > 0, "second run must hit the persisted memo:\n{stderr2}");

    // Service traffic shows up in `snipsnap report`.
    let recorded = std::fs::read_to_string(results.join("serve.jsonl")).unwrap();
    assert_eq!(recorded.lines().count(), 2, "{recorded}");
    let out = snipsnap()
        .args(["report", "--dir", results.to_str().unwrap()])
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("serve"));
}

/// `serve --once` with nothing on stdin is an error, not a silent 0.
#[test]
fn serve_once_empty_stdin_fails() {
    let out = run_with_stdin(&["serve", "--once", "--memo", "off", "--results", "off"], "");
    assert!(!out.status.success(), "empty --once must fail");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("no request"), "{stderr}");
}

#[test]
fn xla_selftest_runs_when_artifacts_exist() {
    let dir = snipsnap::runtime::Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let out = snipsnap()
        .args(["xla", "--artifacts", dir.to_str().unwrap()])
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("self-test passed"));
}
