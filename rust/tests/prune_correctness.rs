//! Branch-and-bound pruning correctness: the pruned search must return
//! **bit-identical** `(metric value, mapping)` results to an unpruned
//! reference pass — across thread counts (1/3/4, covering the uneven
//! `threads % workers != 0` split), both format modes, and several
//! optimization metrics.  Only the telemetry counters (`evaluations`,
//! cache and prune stats) may differ; the designs may not.
//!
//! This is the executable form of the argument in `docs/SEARCH.md`: the
//! lower bound is order-independent and f64-monotone, so pruning skips
//! only provably-worse protos, and an equal-value proto would lose the
//! `(value, proto id)` tie-break anyway.
//!
//! Both cost backends are covered: the bound dispatches its bits→cycles
//! transform through the selected backend (docs/COST.md), so it remains
//! a true lower bound — and pruning stays enabled — under the
//! contention model too.

use snipsnap::arch::presets;
use snipsnap::cost::{ContentionParams, CostModel, Metric};
use snipsnap::dataflow::mapper::MapperConfig;
use snipsnap::search::{cosearch_workload, FormatMode, SearchConfig, WorkloadResult};
use snipsnap::workload::llm;

fn reduced_llm() -> snipsnap::workload::Workload {
    llm::opt_125m(llm::Phase::prefill_only(64))
}

fn backends() -> [CostModel; 2] {
    [CostModel::Analytical, CostModel::Contention(ContentionParams::default())]
}

fn cfg(
    mode: FormatMode,
    metric: Metric,
    threads: usize,
    prune: bool,
    cost: CostModel,
) -> SearchConfig {
    SearchConfig {
        mode,
        metric,
        threads,
        prune,
        cost,
        mapper: MapperConfig { max_candidates: 600, ..Default::default() },
        ..Default::default()
    }
}

/// Designs and scores equal bit for bit; telemetry intentionally ignored.
fn assert_designs_identical(a: &WorkloadResult, b: &WorkloadResult, what: &str) {
    assert_eq!(a.designs.len(), b.designs.len(), "{what}");
    for (da, db) in a.designs.iter().zip(&b.designs) {
        assert_eq!(da.op_name, db.op_name, "{what}");
        assert_eq!(da.mapping, db.mapping, "{what}: {} mappings diverged", da.op_name);
        assert_eq!(
            da.metric_value.to_bits(),
            db.metric_value.to_bits(),
            "{what}: {} values diverged ({} vs {})",
            da.op_name,
            da.metric_value,
            db.metric_value
        );
        assert_eq!(da.input_format.to_string(), db.input_format.to_string(), "{what}");
        assert_eq!(da.weight_format.to_string(), db.weight_format.to_string(), "{what}");
        assert_eq!(da.report, db.report, "{what}: {} reports diverged", da.op_name);
    }
}

#[test]
fn pruned_search_matches_unpruned_reference_across_threads_and_modes() {
    let arch = presets::arch3();
    let w = reduced_llm();
    for cost in backends() {
        for mode in [FormatMode::Fixed, FormatMode::Search] {
            // Unpruned serial run is the reference for everything else.
            let reference =
                cosearch_workload(&arch, &w, &cfg(mode, Metric::Energy, 1, false, cost));
            let mut saw_pruning = false;
            for threads in [1usize, 3, 4] {
                for prune in [false, true] {
                    let r = cosearch_workload(
                        &arch,
                        &w,
                        &cfg(mode, Metric::Energy, threads, prune, cost),
                    );
                    assert_designs_identical(
                        &reference,
                        &r,
                        &format!("{cost} {mode:?} threads={threads} prune={prune}"),
                    );
                    if prune {
                        saw_pruning |= r.pruned > 0;
                        assert!(r.pruned <= r.protos);
                    } else {
                        assert_eq!(r.pruned, 0, "prune=false must never prune");
                    }
                }
            }
            assert!(
                saw_pruning,
                "{cost} {mode:?}: the lower bound never pruned anything — the \
                 branch-and-bound path is not being exercised"
            );
        }
    }
}

#[test]
fn pruning_preserves_results_for_every_metric() {
    let arch = presets::arch3();
    let w = reduced_llm();
    for cost in backends() {
        for metric in [Metric::Energy, Metric::MemoryEnergy, Metric::Latency, Metric::Edp] {
            let off = cosearch_workload(&arch, &w, &cfg(FormatMode::Fixed, metric, 1, false, cost));
            let on = cosearch_workload(&arch, &w, &cfg(FormatMode::Fixed, metric, 1, true, cost));
            assert_designs_identical(&off, &on, &format!("{cost} {metric:?}"));
            assert!(
                on.evaluations <= off.evaluations,
                "{cost} {metric:?}: pruning increased evaluations ({} vs {})",
                on.evaluations,
                off.evaluations
            );
        }
    }
}

#[test]
fn contention_latency_pruning_is_sound_across_threads() {
    // The latency metric is where the contention backend actually
    // changes the bound's cycle term (burst roundup, derate,
    // decompression): the pruned search must still match the unpruned
    // reference bit for bit at every thread count (pruning stays
    // enabled for this backend — no analytical fallback).
    let arch = presets::arch3();
    let w = reduced_llm();
    let cost = CostModel::Contention(ContentionParams::default());
    let reference =
        cosearch_workload(&arch, &w, &cfg(FormatMode::Fixed, Metric::Latency, 1, false, cost));
    for threads in [1usize, 3, 4] {
        for prune in [false, true] {
            let r = cosearch_workload(
                &arch,
                &w,
                &cfg(FormatMode::Fixed, Metric::Latency, threads, prune, cost),
            );
            assert_designs_identical(
                &reference,
                &r,
                &format!("contention latency threads={threads} prune={prune}"),
            );
            if prune {
                assert!(r.pruned <= r.protos, "prune counter exceeds proto count");
            } else {
                assert_eq!(r.pruned, 0, "prune=false must never prune");
            }
        }
    }
    // That pruning actually *fires* under the contention backend is
    // asserted by pruned_search_matches_unpruned_reference_across_
    // threads_and_modes above (the Energy bound is backend-independent,
    // so the seed suite's guarantee carries over); here the point is
    // that the backend-dispatched cycle term keeps the bound sound.
}

#[test]
fn pruning_saves_meaningful_work() {
    // Not a correctness property, but the reason this machinery exists:
    // on a realistic op the bound should cut a visible share of the
    // order sweeps.  Kept deliberately loose (any nonzero saving passes)
    // so model changes don't turn it flaky.
    let arch = presets::arch3();
    let w = reduced_llm();
    let c = CostModel::Analytical;
    let off = cosearch_workload(&arch, &w, &cfg(FormatMode::Fixed, Metric::Energy, 1, false, c));
    let on = cosearch_workload(&arch, &w, &cfg(FormatMode::Fixed, Metric::Energy, 1, true, c));
    assert!(on.pruned > 0, "no protos pruned");
    assert!(on.evaluations < off.evaluations, "pruning saved no evaluations");
}
