//! Replayable run artifacts, end to end: TOML `[[op]]` workloads →
//! co-search → JSON run-config snapshot → reload → **bit-identical**
//! designs and scores, plus the append-mode bench history and the
//! `snipsnap::report` roll-up over a synthetic results directory.

use snipsnap::config::{self, snapshot};
use snipsnap::cost::CostModel;
use snipsnap::dataflow::MAX_LEVELS;
use snipsnap::search::cosearch_workload;
use snipsnap::util::bench::write_record_at;
use snipsnap::util::json::Json;
use std::path::PathBuf;

const CFG: &str = r#"
[run]
arch = "arch3"
metric = "memory-energy"
mode = "search"

[search]
top_k = 2
max_depth = 3
max_mappings = 150
threads = 2

[[op]]
name = "fc1"
m = 64
n = 64
k = 128
act_density = 0.4
wgt_density = 0.5
count = 2

[[op]]
m = 32
n = 64
k = 64
act_density = 0.25
"#;

/// Same run, latency metric, contention cost backend with tuned
/// per-level knobs — the `[cost]` section must survive the
/// TOML → RunConfig → snapshot → replay loop bit-identically.
const CFG_COST: &str = r#"
[run]
arch = "arch3"
metric = "latency"
mode = "search"

[search]
top_k = 2
max_depth = 3
max_mappings = 150
threads = 2

[cost]
backend = "contention"
bandwidth_derate = 0.8
burst_bits = [1024, 256]
decompress_bits_per_cycle = 2048

[[op]]
name = "fc1"
m = 64
n = 64
k = 128
act_density = 0.4
wgt_density = 0.5
count = 2
"#;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("snipsnap_artifacts_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The acceptance contract: a snapshot fed back through the config
/// loader reproduces bit-identical designs and scores.
#[test]
fn snapshot_replay_is_bit_identical() {
    let cfg = config::load_run_config(CFG).unwrap();
    assert_eq!(cfg.workload.ops[0].name, "fc1");
    assert_eq!(cfg.workload.ops[1].name, "op1");
    let r1 = cosearch_workload(&cfg.arch, &cfg.workload, &cfg.search);

    let snap = snapshot::render(&cfg.arch, &cfg.workload, &cfg.search);
    let cfg2 = config::load_run_config_any(&snap).unwrap();
    let r2 = cosearch_workload(&cfg2.arch, &cfg2.workload, &cfg2.search);

    assert_eq!(r1.total_energy_pj().to_bits(), r2.total_energy_pj().to_bits());
    assert_eq!(r1.memory_energy_pj().to_bits(), r2.memory_energy_pj().to_bits());
    assert_eq!(r1.total_cycles().to_bits(), r2.total_cycles().to_bits());
    assert_eq!(r1.designs.len(), r2.designs.len());
    for (a, b) in r1.designs.iter().zip(&r2.designs) {
        assert_eq!(a.op_name, b.op_name);
        assert_eq!(a.input_format.to_string(), b.input_format.to_string());
        assert_eq!(a.weight_format.to_string(), b.weight_format.to_string());
        assert_eq!(a.metric_value.to_bits(), b.metric_value.to_bits(), "{}", a.op_name);
        assert_eq!(format!("{:?}", a.mapping), format!("{:?}", b.mapping), "{}", a.op_name);
    }

    // The snapshot is a fixed point of render∘load — byte-for-byte.
    let snap2 = snapshot::render(&cfg2.arch, &cfg2.workload, &cfg2.search);
    assert_eq!(snap, snap2);

    // The CFG above has no [cost] section: the replayed config must
    // carry the analytical default, explicitly, in the snapshot.
    assert_eq!(cfg2.search.cost, CostModel::Analytical);
    assert!(snap.contains(r#""backend":"analytical""#), "{snap}");
}

/// A `[cost]`-configured contention run replays bit-identically and its
/// snapshot is a fixed point — tuned per-level knobs included.
#[test]
fn cost_section_survives_snapshot_replay() {
    let cfg = config::load_run_config(CFG_COST).unwrap();
    let CostModel::Contention(params) = cfg.search.cost else {
        panic!("[cost] backend = contention not honored: {:?}", cfg.search.cost)
    };
    // Scalar broadcasts; the array overrides the outermost prefix.
    assert_eq!(params.bandwidth_derate[0], 0.8);
    assert_eq!(params.bandwidth_derate[MAX_LEVELS - 1], 0.8);
    assert_eq!(params.burst_bits[0], 1024.0);
    assert_eq!(params.burst_bits[1], 256.0);
    assert_eq!(params.decompress_bits_per_cycle, Some(2048.0));

    let r1 = cosearch_workload(&cfg.arch, &cfg.workload, &cfg.search);
    let snap = snapshot::render(&cfg.arch, &cfg.workload, &cfg.search);
    assert!(snap.contains(r#""backend":"contention""#), "{snap}");

    let cfg2 = config::load_run_config_any(&snap).unwrap();
    assert_eq!(cfg2.search.cost, cfg.search.cost, "cost config not replayed verbatim");
    let r2 = cosearch_workload(&cfg2.arch, &cfg2.workload, &cfg2.search);
    assert_eq!(r1.total_cycles().to_bits(), r2.total_cycles().to_bits());
    assert_eq!(r1.total_energy_pj().to_bits(), r2.total_energy_pj().to_bits());
    assert_eq!(r1.designs.len(), r2.designs.len());
    for (a, b) in r1.designs.iter().zip(&r2.designs) {
        assert_eq!(a.metric_value.to_bits(), b.metric_value.to_bits(), "{}", a.op_name);
        assert_eq!(format!("{:?}", a.mapping), format!("{:?}", b.mapping), "{}", a.op_name);
    }

    let snap2 = snapshot::render(&cfg2.arch, &cfg2.workload, &cfg2.search);
    assert_eq!(snap, snap2, "snapshot is not a fixed point under [cost]");

    // Same TOML minus [cost] = the analytical default — and it must
    // actually change the search's latency story (contention dominates).
    let stripped: String = {
        let mut out = String::new();
        let mut skipping = false;
        for line in CFG_COST.lines() {
            if line.trim() == "[cost]" {
                skipping = true;
                continue;
            }
            if skipping && line.trim().starts_with('[') {
                skipping = false;
            }
            if !skipping {
                out.push_str(line);
                out.push('\n');
            }
        }
        out
    };
    let cfg_plain = config::load_run_config(&stripped).unwrap();
    assert_eq!(cfg_plain.search.cost, CostModel::Analytical);
    let r_plain = cosearch_workload(&cfg_plain.arch, &cfg_plain.workload, &cfg_plain.search);
    // Slack for the backend-dependent tile-refinement trajectory
    // (rust/tests/cost_backends.rs documents why the whole-search
    // comparison is not exact); per-mapping dominance is exact.
    assert!(
        r1.total_cycles() >= r_plain.total_cycles() * 0.98,
        "contention run undercut the analytical optimum: {} < {}",
        r1.total_cycles(),
        r_plain.total_cycles()
    );
}

/// Every record the harness emits must re-parse (unified schema,
/// non-finite metrics included) and accumulate instead of clobbering.
#[test]
fn bench_history_accumulates_and_reports() {
    let dir = tmpdir("report");
    for (wall, speedup) in [(1.0, 12.0), (1.05, f64::NAN)] {
        assert!(write_record_at(
            &dir,
            "table1_speed",
            wall,
            Json::obj(vec![("geomean_fixed_speedup", Json::num(speedup))]),
        ));
    }
    let scan = snipsnap::report::scan_results(&dir).unwrap();
    assert_eq!(scan.benches.len(), 1);
    assert_eq!(scan.benches[0].bench, "table1_speed");
    assert_eq!(scan.benches[0].records.len(), 2, "history must accumulate");
    let out = snipsnap::report::report(&dir).unwrap();
    assert!(out.contains("table1_speed"), "{out}");
    assert!(out.contains("wall_time_s: 1 -> 1.05"), "{out}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A run-config snapshot written next to the results is picked up (and
/// schema-checked) by the report scanner.
#[test]
fn snapshots_ride_along_in_results() {
    let dir = tmpdir("snap");
    let cfg = config::load_run_config(CFG).unwrap();
    let snap = snapshot::render(&cfg.arch, &cfg.workload, &cfg.search);
    std::fs::write(dir.join("run-0.config.json"), &snap).unwrap();
    assert!(write_record_at(&dir, "demo", 0.1, Json::Null));
    let scan = snipsnap::report::scan_results(&dir).unwrap();
    assert_eq!(scan.snapshots.len(), 1);
    // ...and the ride-along snapshot still replays.
    let replay = std::fs::read_to_string(&scan.snapshots[0]).unwrap();
    assert!(config::load_run_config_any(&replay).is_ok());
    // A corrupted snapshot fails the scan, naming the file.
    std::fs::write(dir.join("bad.config.json"), "{truncated").unwrap();
    let e = snipsnap::report::scan_results(&dir).unwrap_err().to_string();
    assert!(e.contains("bad.config.json"), "{e}");
    let _ = std::fs::remove_dir_all(&dir);
}
