//! Cross-module integration tests: format engine x analyzer x workloads.

use snipsnap::engine::{search_formats, EngineConfig};
use snipsnap::format::named;
use snipsnap::format::space::SpaceConfig;
use snipsnap::sparsity::analyzer::{analytical_cost, cost_from_ne};
use snipsnap::sparsity::exact::{exact_cost, exact_ne};
use snipsnap::sparsity::sample::sample_mask;
use snipsnap::sparsity::SparsityPattern;
use snipsnap::workload::{cnn, gqa, llm, moe};

/// The analytical expectation must track ground truth on sampled tensors
/// for every named format across densities and pattern families.
#[test]
fn analytical_matches_sampled_ground_truth() {
    let (r, c) = (128, 128);
    let patterns = [
        SparsityPattern::Unstructured { density: 0.05 },
        SparsityPattern::Unstructured { density: 0.3 },
        SparsityPattern::Unstructured { density: 0.8 },
        SparsityPattern::Nm { n: 2, m: 4 },
        // 8x8 blocks: 256 blocks keeps per-sample occupancy variance low
        // enough for a 5-sample mean comparison.
        SparsityPattern::Block { br: 8, bc: 8, block_density: 0.25 },
    ];
    for pattern in patterns {
        for f in [
            named::bitmap(r, c),
            named::rle(r, c),
            named::csr(r, c),
            named::coo(r, c),
            named::csb(r, c, 16, 16),
        ] {
            // Average exact cost over several sampled masks.
            let mut exact_bits = 0.0;
            let n_samples = 5;
            for seed in 0..n_samples {
                let mask = sample_mask(&pattern, r, c, 1000 + seed);
                exact_bits += exact_cost(&f, &mask, 16).total_bits();
            }
            exact_bits /= n_samples as f64;
            let analytic = analytical_cost(&f, &pattern, 16).total_bits();
            let rel = (analytic - exact_bits).abs() / exact_bits;
            assert!(
                rel < 0.05,
                "{f} under {pattern:?}: analytic {analytic:.0} vs sampled {exact_bits:.0} ({rel:.3})"
            );
        }
    }
}

/// The engine's chosen format must also win on *sampled* tensors, not
/// just in expectation (no overfitting to the analytical model).
#[test]
fn engine_choice_wins_on_concrete_tensors() {
    let cfg = EngineConfig {
        space: SpaceConfig { max_depth: 3, ..Default::default() },
        ..Default::default()
    };
    let pattern = SparsityPattern::Block { br: 16, bc: 16, block_density: 0.2 };
    let (top, _) = search_formats(128, 128, &pattern, None, &cfg);
    let mask = sample_mask(&pattern, 128, 128, 77);
    let chosen_bits = exact_cost(&top[0].format, &mask, 16).total_bits();
    let bitmap_bits = exact_cost(&named::bitmap(128, 128), &mask, 16).total_bits();
    assert!(
        chosen_bits < bitmap_bits,
        "engine pick {} ({chosen_bits}) lost to bitmap ({bitmap_bits}) on a real tensor",
        top[0].format
    );
}

/// cost_from_ne is provider-agnostic: feeding exact counts reproduces
/// exact_cost for every named format.
#[test]
fn costing_core_is_provider_agnostic() {
    let mask = sample_mask(&SparsityPattern::Unstructured { density: 0.2 }, 64, 64, 3);
    for f in [named::bitmap(64, 64), named::csr(64, 64), named::csb(64, 64, 8, 8)] {
        let via_ne = cost_from_ne(&f, &exact_ne(&f, &mask), 16);
        let direct = exact_cost(&f, &mask, 16);
        assert_eq!(via_ne, direct, "{f}");
    }
}

/// Workload zoo structural invariants across the whole model list,
/// including the GQA/MoE/batched-decode/N:M scenario families.
#[test]
fn workload_zoo_invariants() {
    for w in llm::all_llms()
        .iter()
        .chain(cnn::all_cnns().iter())
        .chain(gqa::all_gqa().iter())
        .chain(moe::all_moe().iter())
        .chain(snipsnap::workload::scenario_zoo().iter())
    {
        assert!(!w.ops.is_empty());
        for op in &w.ops {
            assert!(op.dims.m > 0 && op.dims.n > 0 && op.dims.k > 0, "{}", op.name);
            assert!(op.count > 0);
            let di = op.spec.input.density();
            let dw = op.spec.weight.density();
            assert!((0.0..=1.0).contains(&di) && (0.0..=1.0).contains(&dw));
        }
    }
}

/// SA/SW variants transform sparsity as the paper's §IV-C setup requires.
#[test]
fn sa_sw_variants() {
    let base = llm::opt_6_7b(llm::Phase::prefill_only(128));
    let sa = llm::activation_sparse_variant(base.clone());
    let sw = llm::weight_sparse_variant(base.clone(), 8);
    for op in &sa.ops {
        assert_eq!(op.spec.weight.density(), 1.0, "{}", op.name);
    }
    for (op, base_op) in sw.ops.iter().zip(&base.ops) {
        assert_eq!(op.spec.input.density(), 1.0, "{}", op.name);
        if base_op.spec.weight.density() < 1.0 {
            assert!(matches!(op.spec.weight, SparsityPattern::Block { .. }));
        }
    }
}

/// Named formats instantiate and validate across many tensor shapes
/// (including non-powers of two).
#[test]
fn named_formats_across_shapes() {
    for (r, c) in [(3, 6), (7, 11), (64, 48), (1000, 24), (4096, 11008)] {
        for (_, f) in named::baselines(r, c) {
            f.validate().unwrap();
        }
        named::uop_b(r, c).validate().unwrap();
        named::dense(r, c).validate().unwrap();
    }
}

/// Engine statistics: the full space must dwarf the evaluated subset on
/// paper-sized tensors (the Fig. 6 claim at small scale).
#[test]
fn penalty_prunes_hard_at_scale() {
    let cfg = EngineConfig::default();
    let (_, stats) = search_formats(
        1024,
        1024,
        &SparsityPattern::Unstructured { density: 0.1 },
        None,
        &cfg,
    );
    let full = snipsnap::format::space::full_space_size(1024, 1024, &cfg.space);
    assert!(
        full > 50 * stats.evaluated,
        "space {full} vs evaluated {}",
        stats.evaluated
    );
}
