//! Differential suite for `snipsnap serve` (`snipsnap::serve`).
//!
//! The load-bearing claims, each pinned here:
//!
//! 1. **The memo seam is value-transparent.**  Searches with the
//!    cross-run counts store bound — cold or warm — produce designs,
//!    scores and `evaluations` bit-identical to the memo-off search.
//! 2. **Serving is the search.**  `handle_request` returns the same
//!    designs as a direct `cosearch_workload`, two identical requests
//!    yield byte-identical response lines, and the second reports a
//!    nonzero memo hit rate.
//! 3. **The store persists.**  Flush → reopen → a fresh process's
//!    request is served from disk, still bit-identical.
//! 4. **Budgets are honest.**  A budget that cannot fire changes
//!    nothing; an exhausted budget is an `ok:false` response naming the
//!    starved op, never a panic.
//! 5. **Malformed requests cost one error response**, not the loop.
//! 6. **Concurrency is invisible**: a batched `serve_loop` emits the
//!    same bytes as the serial one, in request order.

use snipsnap::config::{load_run_config, snapshot, RunConfig};
use snipsnap::cost::SharedCounts;
use snipsnap::search::{cosearch_workload, try_cosearch_workload, SearchHooks, WorkloadResult};
use snipsnap::serve::memo::{request_scope, MemoSession, MemoStore};
use snipsnap::serve::{handle_line, serve_loop, SearchRequest, ServeOpts, ServeSummary};
use snipsnap::util::json::Json;
use std::path::PathBuf;

/// Two small ops with **distinct** problem dims: per-op memo scopes
/// differ, so a cold single-threaded run performs no memo hits at all —
/// which lets the cold/warm assertions below be exact.
const SRC: &str = r#"
[run]
arch = "arch3"
mode = "fixed"
[search]
max_mappings = 300
[[op]]
name = "a"
m = 32
n = 32
k = 64
act_density = 0.5
wgt_density = 0.4
[[op]]
name = "b"
m = 48
n = 32
k = 32
act_density = 0.3
wgt_density = 0.6
"#;

fn run_cfg() -> RunConfig {
    load_run_config(SRC).unwrap()
}

/// The request line for [`SRC`] — exactly the run-config snapshot.
fn request_line() -> String {
    let run = run_cfg();
    snapshot::render(&run.arch, &run.workload, &run.search).trim().to_string()
}

/// Wrap a snapshot line with service-level fields (`"id":"r1"`, a
/// budget, ...); the snapshot loader ignores keys it does not know.
fn with_fields(snap_line: &str, extra: &str) -> String {
    format!("{{{extra},{}", &snap_line[1..])
}

/// Designs equal bit for bit (mapping, formats, widths, metric value).
fn assert_identical(a: &WorkloadResult, b: &WorkloadResult, what: &str) {
    assert_eq!(a.designs.len(), b.designs.len(), "{what}");
    for (da, db) in a.designs.iter().zip(&b.designs) {
        assert_eq!(da.op_name, db.op_name, "{what}");
        assert_eq!(da.mapping, db.mapping, "{what}: {} mappings diverged", da.op_name);
        assert_eq!(da.input_format, db.input_format, "{what}: {}", da.op_name);
        assert_eq!(da.weight_format, db.weight_format, "{what}: {}", da.op_name);
        assert_eq!(
            (da.input_bits, da.weight_bits),
            (db.input_bits, db.weight_bits),
            "{what}: {}",
            da.op_name
        );
        assert_eq!(
            da.metric_value.to_bits(),
            db.metric_value.to_bits(),
            "{what}: {} metric diverged",
            da.op_name
        );
    }
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("snipsnap_serve_{name}_{}", std::process::id()))
}

/// Claim 1: memo-on (cold and warm) is bit-identical to memo-off, with
/// identical `evaluations`; the cold pass only misses, the warm pass
/// only hits.
#[test]
fn memo_on_and_off_searches_are_bit_identical() {
    let run = run_cfg();
    let baseline =
        try_cosearch_workload(&run.arch, &run.workload, &run.search, SearchHooks::default())
            .unwrap();

    let store = MemoStore::in_memory();
    let scope = request_scope(&run.arch, &run.workload, &run.search);
    let cold_session = MemoSession::new(&store);
    let cold = try_cosearch_workload(
        &run.arch,
        &run.workload,
        &run.search,
        SearchHooks {
            memo: Some(SharedCounts { store: &cold_session, scope }),
            limiter: None,
        },
    )
    .unwrap();
    assert_identical(&baseline, &cold, "cold store vs memo-off");
    assert_eq!(cold.evaluations, baseline.evaluations, "memo must not change evaluations");
    assert_eq!(cold_session.hits(), 0, "distinct-dim ops cannot hit a cold store");
    assert!(cold_session.misses() > 0, "the cold pass must consult the store");
    assert_eq!(store.len() as u64, cold_session.misses(), "every miss is published");

    let warm_session = MemoSession::new(&store);
    let warm = try_cosearch_workload(
        &run.arch,
        &run.workload,
        &run.search,
        SearchHooks {
            memo: Some(SharedCounts { store: &warm_session, scope }),
            limiter: None,
        },
    )
    .unwrap();
    assert_identical(&baseline, &warm, "warm store vs memo-off");
    assert_eq!(warm.evaluations, baseline.evaluations);
    assert!(warm_session.hits() > 0, "the warm pass must be served from the store");
    assert_eq!(warm_session.misses(), 0, "a warm identical search misses nothing");
}

/// Claim 2: `handle_line` twice over one store — byte-identical
/// responses, direct-search-identical designs, nonzero memo hit rate
/// on the second request only.
#[test]
fn serve_matches_direct_search_and_warms_the_memo() {
    let run = run_cfg();
    let line = with_fields(&request_line(), r#""id":"r1""#);
    let store = MemoStore::in_memory();

    let first = handle_line(&line, Some(&store));
    let second = handle_line(&line, Some(&store));
    let ok = first.result.as_ref().expect("first request must succeed");

    // The service result IS the direct search result.
    let direct = cosearch_workload(&run.arch, &run.workload, &run.search);
    assert_identical(&direct, ok, "serve vs direct search");

    // Deterministic wire: byte-identical lines, parseable, id echoed.
    assert_eq!(first.render(), second.render(), "identical requests must render identically");
    let doc = Json::parse(first.render().trim()).expect("response must be valid JSON");
    assert_eq!(doc.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(doc.get("id").and_then(Json::as_str), Some("r1"));
    let designs = doc.get("designs").and_then(Json::as_arr).expect("designs array");
    assert_eq!(designs.len(), direct.designs.len());
    for (wire, d) in designs.iter().zip(&direct.designs) {
        // Shortest-round-trip floats: the wire metric re-parses to the
        // exact bits the search produced.
        assert_eq!(
            wire.get("metric_value").and_then(Json::as_f64).unwrap().to_bits(),
            d.metric_value.to_bits(),
            "{}",
            d.op_name
        );
        assert_eq!(wire.get("op").and_then(Json::as_str), Some(d.op_name.as_str()));
    }

    // Memo traffic is the one asymmetry — and it lives in stats only.
    assert_eq!(first.stats.memo_hits, 0);
    assert!(second.stats.memo_hits > 0, "second identical request must hit the store");
    assert!(second.stats.memo_hit_rate() > 0.0);
    assert_eq!(
        first.stats.evaluations, second.stats.evaluations,
        "memo hits must not change the evaluations counter"
    );
}

/// Claim 3: flush → reopen (a fresh process) → the store serves the
/// same request from disk, bit-identically.
#[test]
fn memo_store_round_trips_through_disk() {
    let path = tmp("disk");
    let _ = std::fs::remove_file(&path);
    let line = request_line();

    let store = MemoStore::open(&path).unwrap();
    let first = handle_line(&line, Some(&store));
    assert!(first.result.is_ok());
    let written = store.flush().unwrap();
    assert!(written > 0, "the cold request must persist entries");
    drop(store);

    let reopened = MemoStore::open(&path).unwrap();
    assert_eq!(reopened.len(), written, "every flushed entry must reload");
    let second = handle_line(&line, Some(&reopened));
    assert_eq!(first.render(), second.render(), "disk-served response diverged");
    assert!(second.stats.memo_hits > 0, "the reopened store must serve hits");
    let _ = std::fs::remove_file(&path);
}

/// Claim 4: a budget that cannot fire is invisible; an exhausted one is
/// a deterministic `ok:false` response naming the starved op.
#[test]
fn budgets_are_invisible_until_they_fire() {
    let unbudgeted = handle_line(&request_line(), None);
    let generous = handle_line(
        &with_fields(
            &request_line(),
            r#""budget":{"max_protos":10000000,"wall_time_ms":3600000}"#,
        ),
        None,
    );
    assert_eq!(
        unbudgeted.render(),
        generous.render(),
        "an unfired budget must not change the response"
    );
    assert!(!generous.stats.budget_exhausted);

    let starved = handle_line(&with_fields(&request_line(), r#""budget":{"max_protos":0}"#), None);
    let err = starved.result.as_ref().expect_err("a zero budget must fail");
    assert!(err.contains("budget exhausted"), "{err}");
    assert!(err.contains("op a"), "the starved op must be named: {err}");
    assert!(starved.stats.budget_exhausted);
    let doc = Json::parse(starved.render().trim()).unwrap();
    assert_eq!(doc.get("ok"), Some(&Json::Bool(false)));
    assert!(doc.get("error").and_then(Json::as_str).unwrap().contains("budget"));
}

/// Claim 5: malformed lines become parseable `ok:false` responses; the
/// parser rejects each bad shape with a message naming the problem.
#[test]
fn malformed_requests_become_error_responses() {
    let line = request_line();
    let cases: Vec<(String, &str)> = vec![
        ("{not json".to_string(), "request"),
        ("{}".to_string(), "snipsnap_run_config"),
        ("[]".to_string(), "snipsnap_run_config"),
        (line.replace("\"snipsnap_run_config\":1", "\"snipsnap_run_config\":99"), "version"),
        (with_fields(&line, r#""budget":{"max_protos":"many"}"#), "max_protos"),
        (with_fields(&line, r#""budget":{"wall_time":5}"#), "unknown budget cap"),
        (with_fields(&line, r#""budget":7"#), "must be an object"),
        (with_fields(&line, r#""id":7"#), "'id' must be a string"),
    ];
    for (bad, needle) in cases {
        let resp = handle_line(&bad, None);
        let err = resp.result.as_ref().expect_err(&format!("must reject: {bad}"));
        assert!(err.contains(needle), "error for {bad:?} must mention '{needle}', got: {err}");
        let doc = Json::parse(resp.render().trim()).expect("error responses are still JSON");
        assert_eq!(doc.get("ok"), Some(&Json::Bool(false)), "{bad}");
        assert!(SearchRequest::parse(&bad).is_err());
    }
    // A null id / absent budget are fine (defaults).
    let req = SearchRequest::parse(&with_fields(&line, r#""id":null"#)).unwrap();
    assert_eq!(req.id, None);
    assert_eq!(req.budget, Default::default());
}

/// Claim 6: the batched loop emits the serial loop's bytes, in order;
/// blank lines are skipped; per-request records land for `report`.
#[test]
fn serve_loop_is_concurrency_invariant_and_records_traffic() {
    let line = request_line();
    let input = format!("{line}\n\n{line}\n{line}\n");
    let results = tmp("loop_results");
    let _ = std::fs::remove_dir_all(&results);

    let mut serial_out = Vec::new();
    let mut serial_log = Vec::new();
    let store = MemoStore::in_memory();
    let summary = serve_loop(
        &ServeOpts { once: false, jobs: 1, results_dir: Some(results.clone()) },
        Some(&store),
        input.as_bytes(),
        &mut serial_out,
        &mut serial_log,
    )
    .unwrap();
    assert_eq!(summary, ServeSummary { requests: 3, failed: 0 });

    let mut batched_out = Vec::new();
    let mut batched_log = Vec::new();
    let store2 = MemoStore::in_memory();
    serve_loop(
        &ServeOpts { once: false, jobs: 3, results_dir: None },
        Some(&store2),
        input.as_bytes(),
        &mut batched_out,
        &mut batched_log,
    )
    .unwrap();
    assert_eq!(
        serial_out, batched_out,
        "a concurrent batch must emit the serial responses byte for byte"
    );
    assert_eq!(serial_out.iter().filter(|&&b| b == b'\n').count(), 3);

    let log = String::from_utf8(serial_log).unwrap();
    assert!(log.contains("memo_hits="), "stats lines must be greppable:\n{log}");
    assert!(log.contains("workload="), "{log}");

    // The per-request records roll up under `snipsnap report`.
    let recorded = std::fs::read_to_string(results.join("serve.jsonl")).unwrap();
    assert_eq!(recorded.lines().count(), 3, "{recorded}");
    for l in recorded.lines() {
        let rec = Json::parse(l).unwrap();
        assert_eq!(rec.get("bench").and_then(Json::as_str), Some("serve"));
        assert_eq!(rec.get("rows").and_then(|r| r.get("ok")), Some(&Json::Bool(true)));
    }
    let rollup = snipsnap::report::report(&results).unwrap();
    assert!(rollup.contains("serve"), "report must include service traffic:\n{rollup}");
    let _ = std::fs::remove_dir_all(&results);
}

/// `--once` semantics: exactly one request, and an empty stdin is an
/// error instead of a silent no-op.
#[test]
fn once_mode_serves_one_request_or_errors() {
    let err = serve_loop(
        &ServeOpts { once: true, jobs: 1, results_dir: None },
        None,
        "".as_bytes(),
        &mut Vec::new(),
        &mut Vec::new(),
    )
    .unwrap_err();
    assert!(err.to_string().contains("no request"), "{err}");

    let line = request_line();
    let input = format!("{line}\n{line}\n");
    let mut out = Vec::new();
    let summary = serve_loop(
        &ServeOpts { once: true, jobs: 4, results_dir: None },
        None,
        input.as_bytes(),
        &mut out,
        &mut Vec::new(),
    )
    .unwrap();
    assert_eq!(summary, ServeSummary { requests: 1, failed: 0 });
    assert_eq!(out.iter().filter(|&&b| b == b'\n').count(), 1, "--once must stop after one");
}

/// `--memo-max-entries`: the cap is enforced at flush through the
/// canonical rewrite — the smallest keys survive, eviction depends only
/// on (entries, cap) and never on insert order, the capped file reloads
/// exactly, and a cap at or above the entry count is a no-op.
#[test]
fn memo_max_entries_caps_at_flush_with_deterministic_eviction() {
    use snipsnap::dataflow::{AccessCounts, MAX_LEVELS};
    use snipsnap::util::inline::InlineVec;

    let counts = |seed: f64| {
        let mut fills: InlineVec<[f64; 3], MAX_LEVELS> = InlineVec::new();
        fills.push([seed, seed * 2.0, seed + 0.125]);
        fills.push([1.0, f64::from_bits(0x3ff0_0000_0000_0001), 3.0e16]);
        AccessCounts { fills }
    };

    let path = tmp("cap");
    let _ = std::fs::remove_file(&path);
    let mut store = MemoStore::open(&path).unwrap();
    store.set_max_entries(Some(4));
    // Insert in descending key order: the surviving set must be a
    // function of the keys, not of insert order.
    for k in (0..10u128).rev() {
        store.insert(k, &counts(k as f64));
    }
    assert_eq!(store.len(), 10, "the cap is enforced at flush, not per insert");
    store.flush().unwrap();
    assert_eq!(store.len(), 4);
    for k in 0..4u128 {
        assert!(store.get(k).is_some(), "smallest keys must survive: {k}");
    }
    for k in 4..10u128 {
        assert!(store.get(k).is_none(), "largest keys must evict: {k}");
    }

    // The rewrite is canonical: the capped file reloads to the capped
    // map, and a second flush with nothing pending is byte-stable.
    let text = std::fs::read_to_string(&path).unwrap();
    let re = MemoStore::open(&path).unwrap();
    assert_eq!(re.len(), 4);
    assert_eq!(re.get(0), Some(counts(0.0)));
    store.flush().unwrap();
    assert_eq!(std::fs::read_to_string(&path).unwrap(), text);
    let _ = std::fs::remove_file(&path);

    // A cap at or above the entry count must not evict (in-memory
    // stores enforce the cap at flush too).
    let mut roomy = MemoStore::in_memory();
    roomy.set_max_entries(Some(8));
    for k in 0..5u128 {
        roomy.insert(k, &counts(k as f64));
    }
    roomy.flush().unwrap();
    assert_eq!(roomy.len(), 5, "a cap above the entry count must not evict");
}
