//! End-to-end co-search integration: SnipSnap vs the baselines on real
//! (reduced) workloads across the Table II architectures.

use snipsnap::arch::presets;
use snipsnap::baselines::sparseloop_like::stepwise_workload;
use snipsnap::cost::Metric;
use snipsnap::dataflow::mapper::MapperConfig;
use snipsnap::search::{cosearch_workload, FormatMode, SearchConfig};
use snipsnap::workload::llm;

fn reduced_llm() -> snipsnap::workload::Workload {
    // OPT-125M with a short prefill keeps dims real but the search quick.
    llm::opt_125m(llm::Phase::prefill_only(64))
}

fn quick(mode: FormatMode) -> SearchConfig {
    SearchConfig {
        mode,
        mapper: MapperConfig { max_candidates: 1_500, ..Default::default() },
        ..Default::default()
    }
}

#[test]
fn cosearch_runs_on_all_table2_archs() {
    let w = reduced_llm();
    for arch in presets::all_table2() {
        let r = cosearch_workload(&arch, &w, &quick(FormatMode::Fixed));
        assert_eq!(r.designs.len(), w.ops.len(), "{}", arch.name);
        assert!(r.total_energy_pj() > 0.0);
        for d in &r.designs {
            d.mapping
                .validate(&w.ops.iter().find(|o| o.name == d.op_name).unwrap().dims)
                .unwrap();
        }
    }
}

#[test]
fn format_search_never_loses_to_fixed() {
    let w = reduced_llm();
    for arch in [presets::arch1(), presets::arch3()] {
        let fixed = cosearch_workload(&arch, &w, &quick(FormatMode::Fixed));
        let search = cosearch_workload(&arch, &w, &quick(FormatMode::Search));
        assert!(
            search.total_energy_pj() <= fixed.total_energy_pj() * 1.001,
            "{}: search {} vs fixed {}",
            arch.name,
            search.total_energy_pj(),
            fixed.total_energy_pj()
        );
    }
}

#[test]
fn progressive_beats_stepwise_on_speed_same_space() {
    let w = reduced_llm();
    let arch = presets::arch3();
    let mapper = MapperConfig { max_candidates: 400, ..Default::default() };
    let snip = cosearch_workload(
        &arch,
        &w,
        &SearchConfig { mode: FormatMode::Fixed, mapper: mapper.clone(), ..Default::default() },
    );
    let sl = stepwise_workload(&arch, &w, &mapper, Metric::Energy);
    // Workflow claim: strictly fewer evaluations (the wall-clock speedup
    // in Table I follows; evaluations are the deterministic proxy).
    assert!(
        sl.evaluations * 2 > 3 * snip.evaluations,
        "stepwise {} vs progressive {}",
        sl.evaluations,
        snip.evaluations
    );
    // Quality must remain comparable.
    let ratio = snip.total_energy_pj() / sl.total_energy_pj();
    assert!(ratio < 1.25, "quality ratio {ratio}");
}

/// Bit-identical equality of two workload results: same designs, same
/// scores (compared as raw f64 bits), same evaluation counts.
fn assert_bit_identical(
    a: &snipsnap::search::WorkloadResult,
    b: &snipsnap::search::WorkloadResult,
) {
    assert_eq!(a.evaluations, b.evaluations, "evaluation counts diverged");
    assert_eq!(a.designs.len(), b.designs.len());
    for (da, db) in a.designs.iter().zip(&b.designs) {
        assert_eq!(da.op_name, db.op_name);
        assert_eq!(da.mapping, db.mapping, "{}: mappings diverged", da.op_name);
        assert_eq!(
            da.metric_value.to_bits(),
            db.metric_value.to_bits(),
            "{}: {} vs {}",
            da.op_name,
            da.metric_value,
            db.metric_value
        );
        assert_eq!(da.input_format.to_string(), db.input_format.to_string());
        assert_eq!(da.weight_format.to_string(), db.weight_format.to_string());
        assert_eq!(da.report, db.report, "{}: cost reports diverged", da.op_name);
    }
}

/// The determinism contract of docs/SEARCH.md: `threads = 1` and
/// `threads = 4` return identical best mappings and scores, on both an
/// LLM and a CNN example workload.  With more ops than threads this
/// exercises the op-level sharding path.
///
/// Pruning is disabled here so the *full* telemetry invariant
/// (`evaluations` identical across thread counts) is exercised; the
/// prune-on design invariance across threads lives in
/// `rust/tests/prune_correctness.rs`.
#[test]
fn parallel_cosearch_is_bit_identical_to_serial() {
    let arch = presets::arch3();

    // LLM workload, full format search.
    let w = reduced_llm();
    let mk = |threads: usize| SearchConfig {
        threads,
        prune: false,
        mapper: MapperConfig { max_candidates: 800, ..Default::default() },
        ..Default::default()
    };
    let serial = cosearch_workload(&arch, &w, &mk(1));
    let par = cosearch_workload(&arch, &w, &mk(4));
    assert_bit_identical(&serial, &par);
    assert!(par.cache.hits > 0, "memoization never fired: {:?}", par.cache);

    // CNN workload (im2col convs; Fixed mode keeps the test quick).
    let mut cnn = snipsnap::workload::cnn::alexnet();
    cnn.ops.truncate(3);
    let mkf = |threads: usize| SearchConfig {
        threads,
        prune: false,
        mode: FormatMode::Fixed,
        mapper: MapperConfig { max_candidates: 600, ..Default::default() },
        ..Default::default()
    };
    let serial = cosearch_workload(&arch, &cnn, &mkf(1));
    let par = cosearch_workload(&arch, &cnn, &mkf(3));
    assert_bit_identical(&serial, &par);
}

/// A single-op workload with threads > 1 forces the within-op proto
/// arena sharding and its `(value, proto-id)` reduction (prune off so
/// the evaluation counts are thread-invariant too).
#[test]
fn proto_sharding_within_one_op_is_bit_identical() {
    let arch = presets::arch3();
    let w = snipsnap::workload::Workload {
        name: "one-op".into(),
        ops: vec![snipsnap::workload::MatMulOp {
            name: "fc".into(),
            dims: snipsnap::dataflow::ProblemDims::new(128, 256, 128),
            spec: snipsnap::sparsity::SparsitySpec::unstructured(0.3, 0.5),
            count: 1,
        }],
    };
    let mk = |threads: usize| SearchConfig {
        threads,
        prune: false,
        mapper: MapperConfig { max_candidates: 1_000, ..Default::default() },
        ..Default::default()
    };
    let serial = cosearch_workload(&arch, &w, &mk(1));
    for threads in [2, 4, 7] {
        let par = cosearch_workload(&arch, &w, &mk(threads));
        assert_bit_identical(&serial, &par);
    }
}

#[test]
fn search_is_deterministic() {
    let w = reduced_llm();
    let arch = presets::arch3();
    let a = cosearch_workload(&arch, &w, &quick(FormatMode::Search));
    let b = cosearch_workload(&arch, &w, &quick(FormatMode::Search));
    assert_eq!(a.total_energy_pj(), b.total_energy_pj());
    assert_eq!(a.evaluations, b.evaluations);
    for (da, db) in a.designs.iter().zip(&b.designs) {
        assert_eq!(da.input_format, db.input_format);
        assert_eq!(da.mapping, db.mapping);
    }
}

#[test]
fn metric_priority_changes_the_winner_sensibly() {
    let w = reduced_llm();
    let arch = presets::arch3();
    let for_energy = cosearch_workload(
        &arch,
        &w,
        &SearchConfig { metric: Metric::Energy, ..quick(FormatMode::Fixed) },
    );
    let for_latency = cosearch_workload(
        &arch,
        &w,
        &SearchConfig { metric: Metric::Latency, ..quick(FormatMode::Fixed) },
    );
    // Each specialist must win (or tie) its own metric.
    assert!(for_energy.total_energy_pj() <= for_latency.total_energy_pj() * 1.001);
    assert!(for_latency.total_cycles() <= for_energy.total_cycles() * 1.001);
}

#[test]
fn config_file_end_to_end() {
    let cfg = snipsnap::config::load_run_config(
        r#"
[run]
arch = "arch3"
metric = "memory-energy"
mode = "fixed"
[search]
max_mappings = 500
[op.fc]
m = 64
n = 128
k = 64
act_density = 0.3
wgt_density = 0.4
"#,
    )
    .expect("config");
    let r = cosearch_workload(&cfg.arch, &cfg.workload, &cfg.search);
    assert_eq!(r.designs.len(), 1);
    assert!(r.memory_energy_pj() > 0.0);
}
