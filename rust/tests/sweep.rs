//! Sweep plans and the multi-process coordinator (`snipsnap sweep`).
//!
//! The load-bearing claims, each pinned here:
//!
//! 1. **Expansion is deterministic odometer order.**  Axes cross in
//!    file order, first axis slowest, ids zero-padded so lexicographic
//!    order equals plan order; the shared base config (max_mappings,
//!    mode, arch) carries into every entry.
//! 2. **Bad plans fail loudly**: unknown axis keys, empty value lists,
//!    duplicate axes, overrides aimed at inline workloads, and roll-up
//!    names that cannot be filenames.
//! 3. **The merged roll-up is worker-count invariant.**  The same plan
//!    at `--workers 1` and `--workers 3` produces byte-identical
//!    `<name>.sweep.jsonl` files, in plan order, and `snipsnap report`
//!    rolls the sweep up like a single run.

use snipsnap::config::sweep::load_sweep_plan;
use snipsnap::cost::Metric;
use std::process::Command;

const PLAN: &str = r#"
[run]
arch = "arch3"
mode = "fixed"

[workload]
preset = "gqa-tiny"
prefill_tokens = 32
decode_tokens = 4

[search]
max_mappings = 150

[sweep]
name = "demo"

[[sweep.axis]]
key = "metric"
values = ["energy", "latency", "frontier"]
"#;

/// Claim 1: one axis expands in value order with the base config
/// applied to every entry.
#[test]
fn plan_expands_with_padded_ids_and_shared_base() {
    let plan = load_sweep_plan(PLAN).unwrap();
    assert_eq!(plan.name, "demo");
    let ids: Vec<&str> = plan.entries.iter().map(|e| e.id.as_str()).collect();
    assert_eq!(ids, ["demo-0", "demo-1", "demo-2"]);
    let metrics: Vec<Metric> = plan.entries.iter().map(|e| e.run.search.metric).collect();
    assert_eq!(metrics, [Metric::Energy, Metric::Latency, Metric::Frontier]);
    for e in &plan.entries {
        assert_eq!(e.run.search.mapper.max_candidates, 150, "{}: base [search] lost", e.id);
        assert_eq!(e.run.arch.name, plan.entries[0].run.arch.name, "{}: base arch lost", e.id);
    }
}

/// Claim 1: two axes cross in odometer order — first axis slowest.
#[test]
fn cross_product_walks_first_axis_slowest() {
    let src = r#"
[run]
arch = "arch3"
mode = "fixed"

[[sweep.axis]]
key = "workload"
values = ["gqa-tiny", "moe-tiny"]

[[sweep.axis]]
key = "threads"
values = [1, 2]
"#;
    let plan = load_sweep_plan(src).unwrap();
    assert_eq!(plan.name, "sweep", "the name defaults without a [sweep] header");
    assert_eq!(plan.entries.len(), 4);
    let wl = |i: usize| plan.entries[i].run.workload.name.to_ascii_lowercase();
    let th = |i: usize| plan.entries[i].run.search.threads;
    assert!(wl(0).contains("gqa") && th(0) == 1, "{} t{}", wl(0), th(0));
    assert!(wl(1).contains("gqa") && th(1) == 2, "{} t{}", wl(1), th(1));
    assert!(wl(2).contains("moe") && th(2) == 1, "{} t{}", wl(2), th(2));
    assert!(wl(3).contains("moe") && th(3) == 2, "{} t{}", wl(3), th(3));
}

/// Claim 1: ids pad to the widest index so they sort in plan order.
#[test]
fn ids_zero_pad_to_the_widest_index() {
    let src = r#"
[run]
arch = "arch3"
mode = "fixed"

[workload]
preset = "gqa-tiny"

[[sweep.axis]]
key = "threads"
values = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11]
"#;
    let plan = load_sweep_plan(src).unwrap();
    assert_eq!(plan.entries.len(), 11);
    assert_eq!(plan.entries[0].id, "sweep-00");
    assert_eq!(plan.entries[10].id, "sweep-10");
    let mut ids: Vec<&str> = plan.entries.iter().map(|e| e.id.as_str()).collect();
    let in_plan_order = ids.clone();
    ids.sort();
    assert_eq!(ids, in_plan_order, "lexicographic order must equal plan order");
}

/// A plan with no axes is a single-config sweep, not an error.
#[test]
fn plan_without_axes_yields_one_entry() {
    let src = r#"
[run]
arch = "arch3"
mode = "fixed"

[workload]
preset = "gqa-tiny"
"#;
    let plan = load_sweep_plan(src).unwrap();
    assert_eq!(plan.entries.len(), 1);
    assert_eq!(plan.entries[0].id, "sweep-0");
}

/// Claim 2: malformed plans fail with messages naming the problem.
#[test]
fn bad_plans_fail_loudly() {
    let base = "[run]\narch = \"arch3\"\nmode = \"fixed\"\n\
                [workload]\npreset = \"gqa-tiny\"\n";
    let expect = |extra: &str, needle: &str| {
        let err = load_sweep_plan(&format!("{base}{extra}"))
            .expect_err(&format!("must reject: {extra}"));
        let msg = format!("{err:#}");
        assert!(msg.contains(needle), "error for {extra:?} must mention '{needle}': {msg}");
    };
    expect("[[sweep.axis]]\nkey = \"metrik\"\nvalues = [\"energy\"]\n", "unknown key 'metrik'");
    expect("[[sweep.axis]]\nkey = \"metric\"\nvalues = []\n", "has no values");
    expect(
        "[[sweep.axis]]\nkey = \"metric\"\nvalues = [\"energy\"]\n\
         [[sweep.axis]]\nkey = \"metric\"\nvalues = [\"latency\"]\n",
        "duplicate axis 'metric'",
    );
    expect("[sweep]\nname = \"de mo\"\n", "[sweep] name");
    expect(
        "[[sweep.axis]]\nkey = \"metric\"\nvalues = [7]\n",
        "values must be strings",
    );

    // A workload axis cannot override an inline [[op]] workload.
    let inline = "[run]\narch = \"arch3\"\nmode = \"fixed\"\n\
                  [[op]]\nname = \"g\"\nm = 32\nn = 32\nk = 32\n\
                  act_density = 0.5\nwgt_density = 0.5\n\
                  [[sweep.axis]]\nkey = \"workload\"\nvalues = [\"gqa-tiny\"]\n";
    let err = load_sweep_plan(inline).expect_err("inline workload + workload axis");
    assert!(format!("{err:#}").contains("cannot be applied"), "{err:#}");
}

fn snipsnap() -> Command {
    Command::new(env!("CARGO_BIN_EXE_snipsnap"))
}

/// Claim 3 (the sweep acceptance test): the merged roll-up is
/// byte-identical at any worker count, holds plan order, and reports.
#[test]
fn sweep_merged_output_is_worker_count_invariant() {
    let dir = std::env::temp_dir().join(format!("snipsnap_sweep_cli_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let plan = dir.join("plan.toml");
    std::fs::write(&plan, PLAN).unwrap();

    let out1 = dir.join("w1");
    let out3 = dir.join("w3");
    for (workers, out_dir) in [("1", &out1), ("3", &out3)] {
        let out = snipsnap()
            .args([
                "sweep",
                "--plan",
                plan.to_str().unwrap(),
                "--workers",
                workers,
                "--out",
                out_dir.to_str().unwrap(),
            ])
            .output()
            .expect("run");
        assert!(
            out.status.success(),
            "--workers {workers}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(stderr.contains("3 configs"), "{stderr}");
        assert!(out.stdout.is_empty(), "the roll-up belongs in --out, not on stdout");
    }

    let merged1 = std::fs::read_to_string(out1.join("demo.sweep.jsonl")).unwrap();
    let merged3 = std::fs::read_to_string(out3.join("demo.sweep.jsonl")).unwrap();
    assert_eq!(merged1, merged3, "merged roll-up must be byte-identical at any worker count");
    let lines: Vec<&str> = merged1.lines().collect();
    assert_eq!(lines.len(), 3, "{merged1}");
    for (i, l) in lines.iter().enumerate() {
        assert!(l.contains(&format!("\"id\":\"demo-{i}\"")), "plan order lost:\n{l}");
        assert!(l.contains("\"ok\":true"), "{l}");
    }
    assert!(
        lines[2].contains("\"frontier\""),
        "the frontier config's Pareto stats must survive the wire:\n{}",
        lines[2]
    );

    // The sweep rolls up under `snipsnap report` like a single run.
    let out = snipsnap()
        .args(["report", "--dir", out1.to_str().unwrap()])
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("Sweep 'demo'"), "{stdout}");
    assert!(stdout.contains("demo-2"), "{stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}
