//! Property-based tests over the core invariants (custom harness in
//! `snipsnap::util::proptest` — proptest is unavailable offline).

use snipsnap::config;
use snipsnap::dataflow::mapper::{all_orders, spatial_candidates};
use snipsnap::dataflow::nest::simulate_fills;
use snipsnap::dataflow::{access_counts, LoopDim, Mapping, ProblemDims, Spatial, TileLevel};
use snipsnap::format::space::{enumerate_allocations, enumerate_patterns, SpaceConfig};
use snipsnap::sparsity::analyzer::{analytical_cost, expected_ne};
use snipsnap::sparsity::exact::exact_ne;
use snipsnap::sparsity::sample::sample_mask;
use snipsnap::sparsity::SparsityPattern;
use snipsnap::util::json::Json;
use snipsnap::util::proptest::{run, Gen};
use snipsnap::workload::llm::{build_llm, weight_nm_variant, LlmShape, LlmSparsity, Phase};
use snipsnap::workload::moe::{build_moe, MoeShape};
use snipsnap::workload::Workload;

fn random_mapping(g: &mut Gen, p: &ProblemDims, nlevels: usize) -> Mapping {
    let orders = all_orders();
    let spatials = spatial_candidates(p, 4, 4, 0.0);
    let spatial = *g.choose(&spatials);
    let mut levels = Vec::with_capacity(nlevels);
    let mut rem = [
        p.m / spatial.factor(LoopDim::M),
        p.n / spatial.factor(LoopDim::N),
        p.k / spatial.factor(LoopDim::K),
    ];
    for lvl in 0..nlevels {
        let mut factors = [1u64; 3];
        for d in 0..3 {
            if lvl == nlevels - 1 {
                factors[d] = rem[d];
            } else {
                let divs = snipsnap::util::mathx::divisors(rem[d]);
                factors[d] = *g.choose(&divs);
                rem[d] /= factors[d];
            }
        }
        levels.push(TileLevel { factors, order: *g.choose(&orders) });
    }
    Mapping { levels, spatial }
}

/// The closed-form access counting must equal the brute-force loop-nest
/// simulation on random small mappings — the cost model's bedrock.
#[test]
fn access_counts_match_simulation() {
    run("access_counts == simulate_fills", 60, |g| {
        let dims = [2u64, 4, 8];
        let p = ProblemDims::new(*g.choose(&dims), *g.choose(&dims), *g.choose(&dims));
        let nlevels = g.usize_in(1, 3);
        let m = random_mapping(g, &p, nlevels);
        m.validate(&p).unwrap();
        let sim = simulate_fills(&m, &p);
        let closed = access_counts(&m, &p);
        for b in 0..nlevels {
            for oi in 0..3 {
                assert_eq!(
                    sim[b][oi], closed.fills[b][oi],
                    "mismatch at boundary {b} operand {oi} for {m}"
                );
            }
        }
    });
}

/// Analytical expected occupancy must converge to the Monte-Carlo mean.
#[test]
fn expected_ne_matches_monte_carlo() {
    run("expected_ne ~= monte carlo", 12, |g| {
        let density = g.f64_in(0.05, 0.95);
        let pattern = SparsityPattern::Unstructured { density };
        let f = match g.usize_in(0, 2) {
            0 => snipsnap::format::named::bitmap(32, 32),
            1 => snipsnap::format::named::csr(32, 32),
            _ => snipsnap::format::named::csb(32, 32, 8, 8),
        };
        let expect = expected_ne(&f, &pattern);
        let trials = 40;
        let mut mean = vec![0.0; expect.len()];
        for t in 0..trials {
            let mask = sample_mask(&pattern, 32, 32, g.rng.next_u64() ^ t);
            for (i, v) in exact_ne(&f, &mask).iter().enumerate() {
                mean[i] += v / trials as f64;
            }
        }
        for (i, (e, m)) in expect.iter().zip(&mean).enumerate() {
            let tol = (m * 0.15).max(2.5);
            assert!(
                (e - m).abs() < tol,
                "{f} boundary {i}: expected {e:.2} vs MC {m:.2} (density {density:.3})"
            );
        }
    });
}

/// Format cost is monotone non-decreasing in density for every pattern
/// the enumerator emits (more non-zeros can never shrink the encoding).
#[test]
fn format_cost_monotone_in_density() {
    run("cost monotone in density", 20, |g| {
        let cfg = SpaceConfig { max_depth: 3, ..Default::default() };
        let pats = enumerate_patterns(&cfg);
        let pat = g.choose(&pats).clone();
        let allocs = enumerate_allocations(&pat, 16, 16, &cfg);
        if allocs.is_empty() {
            return;
        }
        let f = g.choose(&allocs).clone();
        let d1 = g.f64_in(0.0, 0.5);
        let d2 = d1 + g.f64_in(0.0, 1.0 - d1);
        let c1 = analytical_cost(&f, &SparsityPattern::Unstructured { density: d1 }, 16);
        let c2 = analytical_cost(&f, &SparsityPattern::Unstructured { density: d2 }, 16);
        assert!(
            c1.total_bits() <= c2.total_bits() + 1e-6,
            "{f}: cost({d1:.3})={} > cost({d2:.3})={}",
            c1.total_bits(),
            c2.total_bits()
        );
    });
}

/// Every enumerated allocation covers the tensor exactly and validates.
#[test]
fn allocations_always_validate() {
    run("allocations validate", 30, |g| {
        let cfg = SpaceConfig::default();
        let pats = enumerate_patterns(&cfg);
        let pat = g.choose(&pats).clone();
        let rows = g.dim(256).max(2);
        let cols = g.dim(256).max(2);
        for f in enumerate_allocations(&pat, rows, cols, &cfg) {
            f.validate().unwrap_or_else(|e| panic!("{f}: {e}"));
        }
    });
}

/// Compressed size never beats the information floor: payload alone is
/// at least nnz x data_bits in expectation for leaf-compressing formats.
#[test]
fn payload_never_below_nnz() {
    run("payload >= nnz * bits", 30, |g| {
        let density = g.density();
        let pattern = SparsityPattern::Unstructured { density };
        let f = snipsnap::format::named::csr(64, 64);
        let cost = analytical_cost(&f, &pattern, 16);
        let nnz = density * 64.0 * 64.0;
        assert!(cost.payload_bits >= nnz * 16.0 - 1e-6);
    });
}

/// Dense tensors: every format costs at least the dense payload; the
/// `dense` format costs exactly that.
#[test]
fn dense_floor_holds() {
    run("dense floor", 20, |g| {
        let cfg = SpaceConfig { max_depth: 3, ..Default::default() };
        let pats = enumerate_patterns(&cfg);
        let pat = g.choose(&pats).clone();
        let allocs = enumerate_allocations(&pat, 16, 32, &cfg);
        if allocs.is_empty() {
            return;
        }
        let f = g.choose(&allocs).clone();
        let c = analytical_cost(&f, &SparsityPattern::Dense, 16);
        let dense_bits = 16.0 * 16.0 * 32.0;
        assert!(
            c.total_bits() >= dense_bits - 1e-6,
            "{f} stores a dense tensor in {} < {dense_bits} bits",
            c.total_bits()
        );
    });
}

/// Mapping tile footprints shrink monotonically toward inner levels.
#[test]
fn tiles_shrink_inward() {
    run("tiles shrink inward", 40, |g| {
        let p = ProblemDims::new(8, 8, 8);
        let m = random_mapping(g, &p, 3);
        m.validate(&p).unwrap();
        for b in 0..2 {
            let (m0, n0, k0) = m.tile_at(b);
            let (m1, n1, k1) = m.tile_at(b + 1);
            assert!(m1 <= m0 && n1 <= n0 && k1 <= k0);
        }
    });
}

/// N:M sampled masks satisfy the analytical density exactly; block masks
/// do so in expectation.
#[test]
fn sampler_matches_pattern_statistics() {
    run("sampler statistics", 15, |g| {
        let m_group = *g.choose(&[4u64, 8]);
        let n = g.u64_in(1, m_group - 1) as u32;
        let pattern = SparsityPattern::Nm { n, m: m_group as u32 };
        let mask = sample_mask(&pattern, 32, 64, g.rng.next_u64());
        let want = (n as f64 / m_group as f64) * 32.0 * 64.0;
        assert_eq!(mask.nnz() as f64, want);
    });
}

/// Spatial candidates never exceed the array and always divide the dims.
#[test]
fn spatial_candidates_are_legal() {
    run("spatial candidates legal", 30, |g| {
        let p = ProblemDims::new(g.dim(128).max(1), g.dim(128).max(1), g.dim(128).max(1));
        let rows = g.u64_in(1, 16);
        let cols = g.u64_in(1, 16);
        for s in spatial_candidates(&p, rows, cols, 0.3) {
            assert!(s.unroll_rows <= rows && s.unroll_cols <= cols);
            assert_eq!(p.m % s.unroll_rows, 0);
            assert_eq!(p.k % s.unroll_cols, 0);
        }
    });
}

/// The greedy co-search never returns a design worse than the canonical
/// (M,N,K)-ordered mapping of the same tiling.
#[test]
fn greedy_ordering_not_worse_than_canonical() {
    run("greedy >= canonical", 8, |g| {
        use snipsnap::cost::{evaluate, CompressionRatios, Metric};
        use snipsnap::sparsity::reduction::ReductionStrategy;
        use snipsnap::sparsity::SparsitySpec;
        let arch = snipsnap::arch::presets::arch3();
        let p = ProblemDims::new(16, 16, 16);
        let proto = random_mapping(g, &p, 3);
        if proto.validate(&p).is_err() {
            return;
        }
        let spec = SparsitySpec::unstructured(0.5, 0.5);
        // Canonical evaluation.
        let mut canonical = proto.clone();
        for l in &mut canonical.levels {
            l.order = [LoopDim::M, LoopDim::N, LoopDim::K];
        }
        let c = evaluate(
            &arch, &p, &canonical, &spec,
            &ReductionStrategy::NONE, &CompressionRatios::DENSE,
        );
        // Exhaustive best over all order combos at level 0 only (cheap
        // proxy for "greedy finds something at least as good at the top
        // boundary").
        let mut best = f64::INFINITY;
        for ord in all_orders() {
            let mut m = canonical.clone();
            m.levels[0].order = ord;
            let r = evaluate(
                &arch, &p, &m, &spec,
                &ReductionStrategy::NONE, &CompressionRatios::DENSE,
            );
            best = best.min(Metric::Energy.of(&r));
        }
        assert!(best <= Metric::Energy.of(&c) + 1e-9);
    });
}

/// The vector lower bound must (a) bound every scalar metric of every
/// legal mapping from below on both cost backends, and (b) agree
/// bit-for-bit with the scalar `lower_bound` of the matching context
/// metric — the one-pass frontier prune is only sound if both hold.
#[test]
fn vector_lower_bound_bounds_every_metric_on_both_backends() {
    run("lower_bound_vec sound + bit-equal to scalar", 12, |g| {
        use snipsnap::cost::{CompressionRatios, CostModel, EvalContext, Metric};
        use snipsnap::dataflow::tiles_of;
        use snipsnap::sparsity::reduction::ReductionStrategy;
        use snipsnap::sparsity::SparsitySpec;
        let arch = snipsnap::arch::presets::arch3();
        let p = ProblemDims::new(16, 16, 16);
        let m = random_mapping(g, &p, arch.levels.len());
        if m.validate(&p).is_err() {
            return;
        }
        let spec = SparsitySpec::unstructured(g.f64_in(0.1, 1.0), g.f64_in(0.1, 1.0));
        let ratios = CompressionRatios::DENSE;
        let red = ReductionStrategy::NONE;
        let factors: Vec<[u64; 3]> = m.levels.iter().map(|l| l.factors).collect();
        let tiles: Vec<[u64; 3]> = tiles_of(&m).iter().copied().collect();
        for model in [CostModel::Analytical, CostModel::Contention(Default::default())] {
            let mut ctx = EvalContext::with_model(&arch, p, Metric::Energy, model);
            let r = ctx.evaluate(&m, &spec, &red, &ratios);
            let vec = ctx.lower_bound_vec(&factors, &tiles, m.spatial, &spec, &red, &ratios);
            for (mi, metric) in Metric::SCALARS.iter().enumerate() {
                let achieved = metric.of(&r);
                assert!(
                    vec[mi] <= achieved,
                    "{model:?} {metric:?}: bound {} above achieved {achieved} for {m}",
                    vec[mi]
                );
                // Bit-equality with the scalar bound of the same metric.
                ctx.metric = *metric;
                let scalar =
                    ctx.lower_bound(&factors, &tiles, m.spatial, &spec, &red, &ratios);
                assert_eq!(
                    vec[mi].to_bits(),
                    scalar.to_bits(),
                    "{model:?} {metric:?}: vec {} != scalar {scalar}",
                    vec[mi]
                );
            }
        }
    });
}

// --- Scenario-zoo builder invariants -----------------------------------

const ZOO_SP: LlmSparsity =
    LlmSparsity { act_proj: 0.55, act_fc1: 0.50, act_fc2: 0.20, attn: 0.30, weight: 0.40 };

fn random_phase(g: &mut Gen) -> Phase {
    Phase::new(g.u64_in(1, 64), g.u64_in(0, 8))
        .with_batch(g.u64_in(1, 3))
        .with_kv_density(g.f64_in(0.05, 1.0))
}

fn random_zoo_workload(g: &mut Gen, phase: Phase) -> Workload {
    let heads = 8;
    let kv_heads = *g.choose(&[1u64, 2, 4, 8]);
    let shape = LlmShape { hidden: 64, intermediate: 128, layers: 2, heads, kv_heads };
    match g.usize_in(0, 2) {
        0 => build_llm("zoo-llm", shape, ZOO_SP, phase),
        1 => build_moe(
            "zoo-moe",
            MoeShape { base: shape, experts: 4, top_k: g.u64_in(1, 4) },
            ZOO_SP,
            phase,
        ),
        _ => {
            let m = *g.choose(&[4u32, 8]);
            let n = g.u64_in(1, m as u64) as u32;
            weight_nm_variant(build_llm("zoo-nm", shape, ZOO_SP, phase), n, m)
        }
    }
}

/// Every op a scenario builder emits keeps both operand densities in
/// `(0, 1]` — the range the cost and reduction models are defined on.
#[test]
fn builder_densities_stay_in_unit_interval() {
    run("builder densities in (0,1]", 40, |g| {
        let phase = random_phase(g);
        let w = random_zoo_workload(g, phase);
        assert!(!w.ops.is_empty(), "{}", w.name);
        for op in &w.ops {
            for d in [op.spec.input.density(), op.spec.weight.density()] {
                assert!(d > 0.0 && d <= 1.0, "{}: density {d}", op.name);
            }
        }
    });
}

/// Total MACs are monotone non-decreasing in the batch size and in the
/// decode-token count (more work can never cost fewer operations).
#[test]
fn total_macs_monotone_in_batch_and_decode() {
    run("macs monotone in batch/decode", 30, |g| {
        let prefill = g.u64_in(1, 32);
        let decode = g.u64_in(0, 8);
        let batch = g.u64_in(1, 3);
        let kv = g.f64_in(0.1, 1.0);
        let mk = |b: u64, d: u64| {
            let ph = Phase::new(prefill, d).with_batch(b).with_kv_density(kv);
            build_llm("mono", LlmShape::mha(64, 128, 2, 8), ZOO_SP, ph).total_macs()
        };
        assert!(mk(batch + 1, decode) >= mk(batch, decode), "batch step shrank MACs");
        assert!(mk(batch, decode + 1) >= mk(batch, decode), "decode step shrank MACs");
    });
}

/// GQA K/V-projection MACs equal the MHA K/V share scaled by exactly
/// `kv_heads / heads` (the defining property of grouped-query attention;
/// the fused MHA QKV MatMul's K/V share is 2/3 of its MACs).
#[test]
fn gqa_kv_projection_macs_scale_with_grouping() {
    run("gqa kv_proj scaling", 30, |g| {
        let heads = 8u64;
        let kv_heads = *g.choose(&[1u64, 2, 4]);
        let phase = random_phase(g);
        let gqa = build_llm(
            "g",
            LlmShape { hidden: 64, intermediate: 128, layers: 2, heads, kv_heads },
            ZOO_SP,
            phase,
        );
        let mha = build_llm("m", LlmShape::mha(64, 128, 2, heads), ZOO_SP, phase);
        let gqa_kv: f64 = gqa
            .ops
            .iter()
            .filter(|o| o.name.contains("kv_proj"))
            .map(|o| o.total_macs())
            .sum();
        let mha_kv: f64 = mha
            .ops
            .iter()
            .filter(|o| o.name.contains("/qkv"))
            .map(|o| o.total_macs() * 2.0 / 3.0)
            .sum();
        let want = mha_kv * kv_heads as f64 / heads as f64;
        assert!(
            (gqa_kv - want).abs() <= 1e-9 * want.max(1.0),
            "kv_heads={kv_heads}: gqa {gqa_kv} vs scaled mha {want}"
        );
    });
}

/// MoE expert MACs scale linearly with top-k under uniform routing
/// (token counts are chosen divisible so the scaling is exact).
#[test]
fn moe_expert_macs_linear_in_topk() {
    run("moe macs linear in top_k", 30, |g| {
        let experts = 4u64;
        // prefill tokens a multiple of `experts` keeps routing exact.
        let phase = Phase::new(4 * g.u64_in(1, 16), g.u64_in(0, 8)).with_batch(g.u64_in(1, 3));
        let expert_macs = |top_k: u64| -> f64 {
            build_moe(
                "k",
                MoeShape { base: LlmShape::mha(64, 128, 2, 8), experts, top_k },
                ZOO_SP,
                phase,
            )
            .ops
            .iter()
            .filter(|o| o.name.contains("expert_"))
            .map(|o| o.total_macs())
            .sum()
        };
        let base = expert_macs(1);
        assert!(base > 0.0);
        for k in 2..=experts {
            let got = expert_macs(k);
            let want = k as f64 * base;
            assert!(
                (got - want).abs() <= 1e-9 * want,
                "top_k={k}: expert MACs {got} vs {want}"
            );
        }
    });
}

// --- Run-artifact round-trip properties (the grown results layer) ------

/// Random JSON values, depth-bounded, covering special floats, deep
/// nesting and unicode/control-character strings.
fn random_json(g: &mut Gen, depth: usize) -> Json {
    let pick = if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) };
    match pick {
        0 => Json::Null,
        1 => Json::Bool(g.bool()),
        2 => Json::Num(random_f64(g)),
        3 => Json::Str(random_string(g)),
        4 => Json::arr((0..g.usize_in(0, 3)).map(|_| random_json(g, depth - 1))),
        _ => Json::Obj(
            (0..g.usize_in(0, 3))
                .map(|_| (random_string(g), random_json(g, depth - 1)))
                .collect(),
        ),
    }
}

fn random_f64(g: &mut Gen) -> f64 {
    match g.usize_in(0, 7) {
        0 => f64::NAN,
        1 => f64::INFINITY,
        2 => f64::NEG_INFINITY,
        3 => 0.0,
        4 => -0.0,
        5 => g.f64_in(-1.0, 1.0),
        6 => g.f64_in(-1e18, 1e18),
        _ => g.f64_in(0.0, 1.0) * 1e-12,
    }
}

fn random_string(g: &mut Gen) -> String {
    let pool = [
        'a', 'Z', '0', ' ', '"', '\\', '/', '\n', '\t', '\r', '\u{1}', '\u{1f}', 'é', '日',
        '🦀', '\u{2028}',
    ];
    (0..g.usize_in(0, 8)).map(|_| *g.choose(&pool)).collect()
}

/// What the writer documents: non-finite numbers come back as null,
/// everything else round-trips exactly.
fn json_normalize(v: &Json) -> Json {
    match v {
        Json::Num(n) if !n.is_finite() => Json::Null,
        Json::Arr(a) => Json::Arr(a.iter().map(json_normalize).collect()),
        Json::Obj(m) => {
            Json::Obj(m.iter().map(|(k, v)| (k.clone(), json_normalize(v))).collect())
        }
        other => other.clone(),
    }
}

/// `Json::parse` must accept every document the writer can produce —
/// special floats, deep nesting and unicode included — and reproduce
/// the written value (modulo the documented non-finite -> null policy).
#[test]
fn json_display_parse_identity() {
    run("Json parse(render(v)) == normalize(v)", 300, |g| {
        let v = random_json(g, 4);
        let rendered = v.to_string();
        let reparsed = Json::parse(&rendered)
            .unwrap_or_else(|e| panic!("writer produced unparseable JSON: {e}\n{rendered}"));
        assert_eq!(reparsed, json_normalize(&v), "render was:\n{rendered}");
        // Rendering is stable: a second render of the reparsed value is
        // byte-identical (the fixed-point the snapshot layer relies on —
        // non-finite inputs already rendered as null the first time).
        assert_eq!(reparsed.to_string(), rendered, "re-render drifted");
    });
}

/// TOML `[[op]]` workloads survive the full artifact pipeline: parse ->
/// typed config -> JSON snapshot render -> reload -> identical snapshot
/// bytes and identical typed fields.
#[test]
fn toml_array_of_tables_roundtrips_through_snapshot() {
    run("[[op]] -> RunConfig -> snapshot fixed point", 40, |g| {
        let nops = g.usize_in(1, 4);
        let mut toml = String::from(
            "[run]\narch = \"arch3\"\nmetric = \"edp\"\nmode = \"fixed\"\n\
             [search]\nmax_mappings = 200\n",
        );
        let mut dims = Vec::new();
        for i in 0..nops {
            let (m, n, k) =
                (g.dim(256).max(2), g.dim(256).max(2), g.dim(256).max(2));
            let ad = (g.u64_in(1, 100) as f64) / 100.0;
            let wd = (g.u64_in(1, 100) as f64) / 100.0;
            let count = g.u64_in(1, 64);
            toml.push_str(&format!(
                "[[op]]\nname = \"op_{i}\"\nm = {m}\nn = {n}\nk = {k}\n\
                 act_density = {ad}\nwgt_density = {wd}\ncount = {count}\n"
            ));
            dims.push((m, n, k, ad, wd, count));
        }
        let cfg = config::load_run_config(&toml).unwrap_or_else(|e| panic!("{e}\n{toml}"));
        assert_eq!(cfg.workload.ops.len(), nops);
        let snap = config::snapshot::render(&cfg.arch, &cfg.workload, &cfg.search);
        let cfg2 = config::load_run_config_any(&snap).unwrap_or_else(|e| panic!("{e}\n{snap}"));
        let snap2 = config::snapshot::render(&cfg2.arch, &cfg2.workload, &cfg2.search);
        assert_eq!(snap, snap2, "snapshot must be a fixed point of render∘load");
        for (i, op) in cfg2.workload.ops.iter().enumerate() {
            let (m, n, k, ad, wd, count) = dims[i];
            assert_eq!(op.name, format!("op_{i}"));
            assert_eq!((op.dims.m, op.dims.n, op.dims.k), (m, n, k));
            assert_eq!(op.spec.input.density(), ad, "{}", op.name);
            assert_eq!(op.spec.weight.density(), wd, "{}", op.name);
            assert_eq!(op.count, count);
        }
    });
}

// Silence unused-import warning for Spatial (used via random_mapping's
// spatial_candidates return type).
#[allow(dead_code)]
fn _type_uses(_: Spatial) {}
